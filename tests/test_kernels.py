"""Pallas kernel validation (interpret mode): shape/dtype sweeps vs the
bit-matched ref.py oracle and the float64 core engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.core import grids, legendre, sht
from repro.kernels import ops as kops
from repro.kernels import ref as kref

KEY = jax.random.PRNGKey(5)


def _setup(l_max, K, m_vals=None):
    g = grids.make_grid("gl", l_max=l_max)
    lm = legendre.log_mu(l_max)
    m_vals = np.arange(l_max + 1) if m_vals is None else np.asarray(m_vals)
    alm = sht.random_alm(KEY, l_max, l_max, K=K)
    a_re = np.real(np.asarray(alm))[m_vals.clip(0)]
    a_im = np.imag(np.asarray(alm))[m_vals.clip(0)]
    a32 = jnp.concatenate([jnp.asarray(a_re), jnp.asarray(a_im)],
                          axis=-1).astype(jnp.float32)
    pmm, pms = kref.prepare_seeds(m_vals, g.sin_theta, lm)
    x32 = jnp.asarray(g.cos_theta, jnp.float32)
    return g, lm, m_vals, a_re, a_im, a32, pmm, pms, x32


@pytest.mark.parametrize("l_max,K", [(24, 1), (40, 2), (33, 4)])
@pytest.mark.parametrize("variant", ["vpu", "mxu"])
@pytest.mark.parametrize("fold", [False, True])
def test_synth_kernel_vs_ref(l_max, K, variant, fold):
    g, lm, m_vals, a_re, a_im, a32, pmm, pms, x32 = _setup(l_max, K)
    nh = (g.n_rings + 1) // 2
    xs = g.cos_theta[:nh] if fold else g.cos_theta
    sins = g.sin_theta[:nh] if fold else g.sin_theta
    pmm_f, pms_f = kref.prepare_seeds(m_vals, sins, lm)
    want = kref.synth_ref(a32, m_vals, jnp.asarray(xs, jnp.float32), pmm_f,
                          pms_f, l_max=l_max, fold=fold)
    got = kops.synth(a32, m_vals, jnp.asarray(xs, jnp.float32), pmm_f, pms_f,
                     l_max=l_max, fold=fold, variant=variant)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=2e-6)


@pytest.mark.parametrize("l_max,K", [(24, 1), (40, 2)])
@pytest.mark.parametrize("variant", ["vpu", "mxu"])
def test_anal_kernel_vs_ref(l_max, K, variant):
    g, lm, m_vals, a_re, a_im, a32, pmm, pms, x32 = _setup(l_max, K)
    rng = np.random.default_rng(0)
    dw = jnp.asarray(rng.normal(size=(len(m_vals), 1, g.n_rings, 2 * K)),
                     jnp.float32)
    want = kref.anal_ref(dw, m_vals, x32, pmm, pms, l_max=l_max, l1p=128)
    got = kops.anal(dw, m_vals, x32, pmm, pms, l_max=l_max, variant=variant)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want[:, : got.shape[1]]),
                               rtol=0, atol=5e-5)


def test_synth_kernel_vs_f64_engine():
    l_max, K = 40, 2
    g, lm, m_vals, a_re, a_im, a32, pmm, pms, x32 = _setup(l_max, K)
    d_re, d_im = legendre.delta_from_alm(a_re, a_im, m_vals, g.cos_theta,
                                         g.sin_theta, lm, l_max=l_max)
    truth = np.concatenate([np.asarray(d_re), np.asarray(d_im)], axis=-1)
    got = np.asarray(kops.synth(a32, m_vals, x32, pmm, pms, l_max=l_max,
                                variant="mxu"))[:, 0]
    rel = np.max(np.abs(got - truth)) / np.max(np.abs(truth))
    assert rel < 5e-5


def test_kernel_handles_plan_padding():
    """-1 m slots (plan padding) must produce exactly zero output."""
    l_max, K = 20, 1
    m_vals = np.array([0, 5, -1, 17, -1])
    g, lm, m_vals, a_re, a_im, a32, pmm, pms, x32 = _setup(l_max, K, m_vals)
    got = np.asarray(kops.synth(a32, m_vals, x32, pmm, pms, l_max=l_max,
                                variant="vpu"))
    assert np.all(got[2] == 0.0) and np.all(got[4] == 0.0)
    assert np.any(got[1] != 0.0)


def test_kernel_f32_rescaling_high_m():
    """f32 seeds underflow ~m=40 at polar rings; the in-kernel rescaled
    recurrence must recover the representable values downstream."""
    l_max = 300
    g = grids.make_grid("gl", l_max=l_max)
    lm = legendre.log_mu(l_max)
    m_vals = np.array([250])
    a = np.zeros((1, l_max + 1, 2), np.float32)
    a[0, l_max, 0] = 1.0
    pmm, pms = kref.prepare_seeds(m_vals, g.sin_theta, lm)
    assert int(jnp.min(pms)) < 0          # scaling actually engaged
    got = np.asarray(kops.synth(jnp.asarray(a), m_vals,
                                jnp.asarray(g.cos_theta, jnp.float32), pmm,
                                pms, l_max=l_max, variant="vpu"))[0, 0, :, 0]
    d_re, _ = legendre.delta_from_alm(
        a[None, :, :, :1][0], np.zeros((1, l_max + 1, 1)), m_vals,
        g.cos_theta, g.sin_theta, lm, l_max=l_max)
    truth = np.asarray(d_re)[0, :, 0]
    assert np.all(np.isfinite(got))
    assert np.max(np.abs(got - truth)) < 5e-4 * np.abs(truth).max()


@pytest.mark.parametrize("variant", ["vpu", "mxu"])
def test_anal_fold_vs_unfold(variant):
    l_max, K = 32, 1
    g, lm, m_vals, a_re, a_im, a32, pmm, pms, x32 = _setup(l_max, K)
    rng = np.random.default_rng(3)
    R = g.n_rings
    dw_full = rng.normal(size=(len(m_vals), R, 2 * K)).astype(np.float32)
    got_u = np.asarray(kops.anal(jnp.asarray(dw_full)[:, None], m_vals, x32,
                                 pmm, pms, l_max=l_max, variant=variant))
    # folded: combine mirror pairs
    nh = (R + 1) // 2
    n_part = dw_full[:, :nh].copy()
    s_part = np.zeros_like(n_part)
    s_part[:, : R - nh] = dw_full[:, nh:][:, ::-1]
    dw_f = jnp.asarray(np.stack([n_part + s_part, n_part - s_part], axis=1))
    pmm_n, pms_n = kref.prepare_seeds(m_vals, g.sin_theta[:nh], lm)
    got_f = np.asarray(kops.anal(dw_f, m_vals,
                                 jnp.asarray(g.cos_theta[:nh], jnp.float32),
                                 pmm_n, pms_n, l_max=l_max, fold=True,
                                 variant=variant))
    assert np.max(np.abs(got_u - got_f)) < 2e-4 * max(1.0, np.abs(got_u).max())
