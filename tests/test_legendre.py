import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

import repro  # noqa: F401
from repro.core import grids, legendre


def _p_matrix(m, l_max, grid):
    """P_lm(x_r) for all l via unit-vector synthesis."""
    lm = legendre.log_mu(l_max)
    P = []
    for l in range(l_max + 1):
        a = np.zeros((1, l_max + 1, 1))
        a[0, l, 0] = 1.0
        d, _ = legendre.delta_from_alm(a, np.zeros_like(a), [m],
                                       grid.cos_theta, grid.sin_theta, lm,
                                       l_max=l_max)
        P.append(np.asarray(d)[0, :, 0])
    return np.stack(P)                   # (L, R)


@pytest.mark.parametrize("m", [0, 1, 7, 16])
def test_orthonormality_on_gl(m):
    l_max = 16
    g = grids.make_grid("gl", l_max=l_max)
    P = _p_matrix(m, l_max, g)
    wring = g.weights * g.n_phi
    G = (P * wring) @ P.T
    sub = G[m:, m:]
    assert np.max(np.abs(sub - np.eye(sub.shape[0]))) < 1e-13


def test_known_values():
    l_max = 4
    g = grids.make_grid("gl", l_max=l_max)
    x = g.cos_theta
    P0 = _p_matrix(0, l_max, g)
    assert np.allclose(P0[0], np.sqrt(1 / (4 * np.pi)))
    assert np.allclose(P0[1], np.sqrt(3 / (4 * np.pi)) * x)
    assert np.allclose(P0[2], np.sqrt(5 / (16 * np.pi)) * (3 * x * x - 1))
    P1 = _p_matrix(1, l_max, g)
    assert np.allclose(P1[1], np.sqrt(3 / (8 * np.pi)) * g.sin_theta)


def test_high_m_underflow_stability():
    """P_mm underflows f64 around m ~ 150 at polar rings without rescaling;
    the scaled recurrence must stay finite and correct through turn-on."""
    l_max = 1400
    g = grids.make_grid("gl", l_max=l_max)
    lm = legendre.log_mu(l_max)
    m = 1200
    a = np.zeros((1, l_max + 1, 1))
    a[0, l_max, 0] = 1.0
    d, _ = legendre.delta_from_alm(a, np.zeros_like(a), [m], g.cos_theta,
                                   g.sin_theta, lm, l_max=l_max)
    d = np.asarray(d)[0, :, 0]
    assert np.all(np.isfinite(d))
    # normalised P values are O(1) near the equator
    assert 0.1 < np.abs(d).max() < 10.0


def test_padding_m_is_inert():
    l_max = 12
    g = grids.make_grid("gl", l_max=l_max)
    lm = legendre.log_mu(l_max)
    a = np.random.default_rng(0).normal(size=(2, l_max + 1, 1))
    d, _ = legendre.delta_from_alm(a, np.zeros_like(a), [3, -1], g.cos_theta,
                                   g.sin_theta, lm, l_max=l_max)
    d = np.asarray(d)
    assert np.all(np.isfinite(d))
    assert np.all(d[1] == 0.0)            # padded slot contributes nothing


def test_folded_matches_unfolded():
    l_max = 24
    g = grids.make_grid("gl", l_max=l_max)
    lm = legendre.log_mu(l_max)
    rng = np.random.default_rng(1)
    a_re = rng.normal(size=(l_max + 1, l_max + 1, 2))
    a_im = rng.normal(size=a_re.shape)
    for m in range(l_max + 1):            # zero sub-diagonal
        a_re[m, :m] = 0
        a_im[m, :m] = 0
    m_vals = np.arange(l_max + 1)
    d_re, d_im = legendre.delta_from_alm(a_re, a_im, m_vals, g.cos_theta,
                                         g.sin_theta, lm, l_max=l_max)
    nh = (g.n_rings + 1) // 2
    ere, eim, ore_, oim = legendre.delta_from_alm_folded(
        a_re, a_im, m_vals, g.cos_theta[:nh], g.sin_theta[:nh], lm,
        l_max=l_max)
    north = np.asarray(ere + ore_)
    south = np.asarray(ere - ore_)[:, : g.n_rings - nh][:, ::-1]
    full = np.concatenate([north, south], axis=1)
    assert np.max(np.abs(full - np.asarray(d_re))) < 1e-12


@settings(max_examples=20, deadline=None)
@given(l=st.integers(2, 40), dm=st.integers(0, 40))
def test_recurrence_vs_direct_formula(l, dm):
    """Property: the scaled recurrence matches the explicit normalised
    Legendre polynomial computed via numpy's unnormalised recurrence."""
    m = max(0, l - dm)
    l_max = l
    g = grids.make_grid("gl", l_max=max(l_max, 4))
    P = _p_matrix(m, l_max, g)[l]
    # direct: P~_lm = N_lm * P_lm with numpy's lpmv-free manual recurrence
    from math import lgamma
    x = g.cos_theta
    # unnormalised P_mm = (-1)^m (2m-1)!! (1-x^2)^(m/2) ... use logs
    dfact = sum(np.log(2 * k - 1) for k in range(1, m + 1))
    pmm = np.exp(dfact + 0.5 * m * np.log(1 - x ** 2))
    p_prev, p_curr = np.zeros_like(x), pmm
    for ll in range(m + 1, l + 1):
        p_next = ((2 * ll - 1) * x * p_curr - (ll - 1 + m) * p_prev) / (ll - m)
        p_prev, p_curr = p_curr, p_next
    lognorm = 0.5 * (np.log(2 * l + 1) - np.log(4 * np.pi)
                     + lgamma(l - m + 1) - lgamma(l + m + 1))
    ref = p_curr * np.exp(lognorm)
    assert np.max(np.abs(P - ref)) < 1e-8 * max(1.0, np.abs(ref).max())
