"""Subprocess helper: distributed SHT == serial engine on 8 host devices.
Prints OK lines; exits nonzero on mismatch."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import numpy as np, jax, jax.numpy as jnp
import repro  # noqa
from repro.core import grids, sht, plan as planlib, dist_sht

key = jax.random.PRNGKey(3)
lmax = 40
g = grids.make_grid("gl", l_max=lmax)
t = sht.SHT(g, l_max=lmax, m_max=lmax)
alm = sht.random_alm(key, lmax, lmax, K=2)
maps_ref = np.asarray(t.alm2map(alm))
alm_ref = np.asarray(t.map2alm(jnp.asarray(maps_ref)))
mesh = jax.make_mesh((4, 2), ("data", "model"))
p = planlib.SHTPlan(g, lmax, lmax, 8)

def check(name, fold, comm_dtype, stage1, dtype, tol_s, tol_a):
    d = dist_sht.DistSHT(p, mesh, ("data", "model"), dtype=dtype, fold=fold,
                         comm_dtype=comm_dtype, stage1=stage1)
    packed = np.asarray(p.pack_alm(np.asarray(alm)))
    if dtype == "float32":
        packed = packed.astype(np.complex64)
    maps_plan = d.alm2map(jnp.asarray(packed))
    maps_grid = np.asarray(p.scatter_map(np.asarray(maps_plan)))
    err_s = np.max(np.abs(maps_grid - maps_ref)) / np.max(np.abs(maps_ref))
    mp = p.gather_map(jnp.asarray(maps_ref).astype(d.dtype))
    alm_out = np.asarray(p.unpack_alm(np.asarray(d.map2alm(mp))))
    err_a = np.max(np.abs(alm_out - alm_ref)) / np.max(np.abs(alm_ref))
    ok = err_s < tol_s and err_a < tol_a
    print(f"{name}: synth={err_s:.2e} anal={err_a:.2e} {'OK' if ok else 'FAIL'}")
    return ok

ok = True
ok &= check("f64", False, None, "jnp", "float64", 1e-12, 1e-12)
ok &= check("f64+fold", True, None, "jnp", "float64", 1e-12, 1e-12)
ok &= check("f64+bf16comm", False, "bfloat16", "jnp", "float64", 2e-2, 2e-2)
ok &= check("f32+pallas", False, None, "pallas", "float32", 5e-4, 5e-4)
ok &= check("f32+pallas+fold", True, None, "pallas", "float32", 5e-4, 5e-4)

# ragged true-HEALPix: bucket-aware ring sharding + bucket phase stage
gh = grids.make_grid("healpix", nside=8)
lmax_h = 16
th = sht.SHT(gh, l_max=lmax_h, m_max=lmax_h)
alm_h = sht.random_alm(jax.random.PRNGKey(4), lmax_h, lmax_h, K=2)
maps_h = np.asarray(th.alm2map(alm_h))
alm_h_ref = np.asarray(th.map2alm(jnp.asarray(maps_h)))
ph = planlib.SHTPlan(gh, lmax_h, lmax_h, 8)
dh = dist_sht.DistSHT(ph, mesh, ("data", "model"))
mg = np.asarray(ph.scatter_map(np.asarray(
    dh.alm2map(jnp.asarray(ph.pack_alm(np.asarray(alm_h)))))))
err_s = np.max(np.abs(mg - maps_h)) / np.max(np.abs(maps_h))
ah = np.asarray(ph.unpack_alm(np.asarray(
    dh.map2alm(ph.gather_map(jnp.asarray(maps_h))))))
err_a = np.max(np.abs(ah - alm_h_ref)) / np.max(np.abs(alm_h_ref))
hp_ok = err_s < 1e-12 and err_a < 1e-12
print(f"f64+healpix-ragged: synth={err_s:.2e} anal={err_a:.2e} "
      f"{'OK' if hp_ok else 'FAIL'}")
ok &= hp_ok

# -- spin-2 (E/B <-> Q/U): the component pair rides the trailing channel
#    axis through the same two-stage path (one all_to_all, 4K channels)
alm_eb = sht.random_alm_spin(jax.random.PRNGKey(5), lmax, lmax, K=2)
maps_qu_ref = np.asarray(t.alm2map_spin(alm_eb))
alm_eb_ref = np.asarray(t.map2alm_spin(jnp.asarray(maps_qu_ref)))


def check_spin(name, stage1, dtype, tol_s, tol_a):
    d = dist_sht.DistSHT(p, mesh, ("data", "model"), dtype=dtype,
                         stage1=stage1)
    packed = np.stack([np.asarray(p.pack_alm(np.asarray(alm_eb[i])))
                       for i in range(2)])
    if dtype == "float32":
        packed = packed.astype(np.complex64)
    mp2 = np.asarray(d.alm2map_spin(jnp.asarray(packed)))
    mg = np.stack([np.asarray(p.scatter_map(mp2[i])) for i in range(2)])
    err_s = np.max(np.abs(mg - maps_qu_ref)) / np.max(np.abs(maps_qu_ref))
    gm = jnp.stack([jnp.asarray(p.gather_map(
        jnp.asarray(maps_qu_ref[i]).astype(d.dtype))) for i in range(2)])
    alm_out = np.asarray(d.map2alm_spin(gm))
    au = np.stack([np.asarray(p.unpack_alm(alm_out[i])) for i in range(2)])
    err_a = np.max(np.abs(au - alm_eb_ref)) / np.max(np.abs(alm_eb_ref))
    s_ok = err_s < tol_s and err_a < tol_a
    print(f"{name}: synth={err_s:.2e} anal={err_a:.2e} "
          f"{'OK' if s_ok else 'FAIL'}")
    return s_ok


ok &= check_spin("f64+spin2", "jnp", "float64", 1e-12, 1e-12)
ok &= check_spin("f32+pallas+spin2", "pallas", "float32", 5e-4, 5e-4)

# -- adjoint-based VJP through shard_map: jax.grad of a scalar loss through
#    the distributed transform matches central finite differences (the
#    custom linear_call rules must transpose across the all_to_all)
rng = np.random.default_rng(7)


def check_grad(name, stage1, dtype, tol):
    d = dist_sht.DistSHT(p, mesh, ("data", "model"), dtype=dtype,
                         stage1=stage1)
    packed = jnp.asarray(p.pack_alm(np.asarray(alm))).astype(
        jnp.complex64 if dtype == "float32" else jnp.complex128)
    t = jnp.asarray(rng.normal(size=(p.r_pad, g.max_n_phi, 2)),
                    jnp.dtype(dtype))

    def loss(a):
        return jnp.sum(d.alm2map(a) * t)

    gr = jax.grad(loss)(packed)
    v = jnp.asarray(rng.normal(size=packed.shape)
                    + 1j * rng.normal(size=packed.shape)).astype(packed.dtype)
    eps = 1e-6 if dtype == "float64" else 1e-2
    fd = float((loss(packed + eps * v) - loss(packed - eps * v)) / (2 * eps))
    dd = float(jnp.real(jnp.sum(gr * v)))      # JAX pairing: Re(g . v)
    err_s = abs(fd - dd) / max(abs(fd), 1e-9)

    maps0 = d.alm2map(packed)

    def loss_a(mp):
        return jnp.sum(jnp.abs(d.map2alm(mp)) ** 2)

    gm = jax.grad(loss_a)(maps0)
    vm = jnp.asarray(rng.normal(size=maps0.shape), maps0.dtype)
    fda = float((loss_a(maps0 + eps * vm) - loss_a(maps0 - eps * vm))
                / (2 * eps))
    err_a = abs(fda - float(jnp.sum(gm * vm))) / max(abs(fda), 1e-9)
    g_ok = err_s < tol and err_a < tol
    print(f"{name}: synth={err_s:.2e} anal={err_a:.2e} "
          f"{'OK' if g_ok else 'FAIL'}")
    return g_ok


ok &= check_grad("grad+f64+jnp", "jnp", "float64", 1e-7)
ok &= check_grad("grad+f32+pallas", "pallas", "float32", 3e-2)

# -- spin-2 ragged healpix through the full plan dispatch (mode="dist")
ps = repro.make_plan("healpix", nside=8, l_max=lmax_h, K=2,
                     dtype="float64", mode="dist", spin=2)
alm_hs = sht.random_alm_spin(jax.random.PRNGKey(6), lmax_h, lmax_h, K=2)
m_ref = np.asarray(th.alm2map_spin(alm_hs))
a_ref = np.asarray(th.map2alm_spin(jnp.asarray(m_ref)))
m_dist = np.asarray(ps.alm2map(alm_hs))
err_s = np.max(np.abs(m_dist - m_ref)) / np.max(np.abs(m_ref))
a_dist = np.asarray(ps.map2alm(jnp.asarray(m_ref)))
err_a = np.max(np.abs(a_dist - a_ref)) / np.max(np.abs(a_ref))
sp_ok = err_s < 1e-12 and err_a < 1e-12
print(f"dist-plan+healpix+spin2: synth={err_s:.2e} anal={err_a:.2e} "
      f"{'OK' if sp_ok else 'FAIL'}")
ok &= sp_ok
sys.exit(0 if ok else 1)
