"""Subprocess helper: chunked pipelined exchange == monolithic all_to_all.

Runs on 4 simulated host devices.  For spin 0 and spin 2, C in {2, 4}
must reproduce the C=1 (monolithic) output bit-identically in f64 for
synthesis and to < 1e-12 for analysis, covering both the K-axis schedule
(K >= C) and the m-axis fallback (K < C).  Also gradchecks jax.grad
through the chunked pipeline against the monolithic gradient, and
verifies the fail-fast ValueError in `_exchange`.

Prints OK lines; exits nonzero on mismatch.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
import numpy as np, jax, jax.numpy as jnp
import repro  # noqa
from repro.core import grids, sht, plan as planlib, dist_sht

key = jax.random.PRNGKey(11)
lmax = 24
g = grids.make_grid("gl", l_max=lmax)
mesh = jax.make_mesh((2, 2), ("data", "model"))
p = planlib.SHTPlan(g, lmax, lmax, 4)
ok = True


def engines(chunk_list, **kw):
    return {c: dist_sht.DistSHT(p, mesh, ("data", "model"), dtype="float64",
                                comm_chunks=c, **kw) for c in chunk_list}


def rel(a, b):
    return np.max(np.abs(a - b)) / max(np.max(np.abs(b)), 1e-300)


def check_spin0(K):
    global ok
    alm = sht.random_alm(jax.random.PRNGKey(K), lmax, lmax, K=K)
    packed = jnp.asarray(p.pack_alm(np.asarray(alm)))
    maps0 = None
    d = engines([1, 2, 4])
    maps = {c: np.asarray(d[c].alm2map(packed)) for c in d}
    maps0 = jnp.asarray(maps[1])
    alms = {c: np.asarray(d[c].map2alm(maps0)) for c in d}
    for c in (2, 4):
        axis, bounds = d[c].plan.chunk_schedule(K, chunks=c)
        bit = bool(np.array_equal(maps[c], maps[1]))
        ea = rel(alms[c], alms[1])
        good = bit and ea < 1e-12
        print(f"spin0 K={K} C={c} [{axis}]: synth bit-identical={bit} "
              f"anal={ea:.2e} {'OK' if good else 'FAIL'}")
        ok &= good


def check_spin2(K):
    global ok
    alm_eb = sht.random_alm_spin(jax.random.PRNGKey(40 + K), lmax, lmax, K=K)
    packed = jnp.stack([jnp.asarray(p.pack_alm(np.asarray(alm_eb[i])))
                        for i in range(2)])
    d = engines([1, 2, 4])
    maps = {c: np.asarray(d[c].alm2map_spin(packed)) for c in d}
    maps0 = jnp.asarray(maps[1])
    alms = {c: np.asarray(d[c].map2alm_spin(maps0)) for c in d}
    for c in (2, 4):
        axis, bounds = d[c].plan.chunk_schedule(K, ncomp=2, chunks=c)
        bit = bool(np.array_equal(maps[c], maps[1]))
        ea = rel(alms[c], alms[1])
        good = bit and ea < 1e-12
        print(f"spin2 K={K} C={c} [{axis}]: synth bit-identical={bit} "
              f"anal={ea:.2e} {'OK' if good else 'FAIL'}")
        ok &= good


check_spin0(K=4)   # K-axis schedule for C=2 and C=4
check_spin0(K=1)   # m-axis fallback for both
check_spin2(K=4)   # K-axis schedule
check_spin2(K=1)   # m-axis fallback

# -- gradient through the chunked pipeline must match the monolithic one
#    (the chunked exchange is the same linear op, so the transposes agree)
rng = np.random.default_rng(13)
alm = sht.random_alm(jax.random.PRNGKey(2), lmax, lmax, K=4)
packed = jnp.asarray(p.pack_alm(np.asarray(alm)))
t = jnp.asarray(rng.normal(size=(p.r_pad, g.max_n_phi, 4)), jnp.float64)
d = engines([1, 2])


def loss(eng, a):
    return jnp.sum(eng.alm2map(a) * t)


g1 = jax.grad(lambda a: loss(d[1], a))(packed)
g2 = jax.grad(lambda a: loss(d[2], a))(packed)
eg = rel(np.asarray(g2), np.asarray(g1))
eps = 1e-6
v = jnp.asarray(rng.normal(size=packed.shape)
                + 1j * rng.normal(size=packed.shape)).astype(packed.dtype)
fd = float((loss(d[2], packed + eps * v) - loss(d[2], packed - eps * v))
           / (2 * eps))
dd = float(jnp.real(jnp.sum(g2 * v)))
efd = abs(fd - dd) / max(abs(fd), 1e-9)
g_ok = eg < 1e-12 and efd < 1e-7
print(f"grad C=2 vs C=1: graddiff={eg:.2e} fd={efd:.2e} "
      f"{'OK' if g_ok else 'FAIL'}")
ok &= g_ok

# -- fail-fast: a slot count that the device count does not divide must
#    raise a ValueError naming the mesh before reaching lax.all_to_all
d1 = dist_sht.DistSHT(p, mesh, ("data", "model"))
try:
    d1._exchange(jnp.zeros((9, 4, 2)), to_rings=False)
    print("fail-fast: no error raised FAIL")
    ok = False
except ValueError as e:
    msg_ok = "mesh" in str(e) and "axis 0" in str(e)
    print(f"fail-fast: ValueError raised, names mesh/axis={msg_ok} "
          f"{'OK' if msg_ok else 'FAIL'}")
    ok &= msg_ok

sys.exit(0 if ok else 1)
