"""Subprocess helper: MoE all-to-all EP path == single-shard reference."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, dataclasses
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
import repro  # noqa
from repro import compat
from repro.configs import registry
from repro.configs.base import reduced
from repro.models import moe as M
from repro.models.transformer import make_rules

cfg = reduced(registry.ARCHS["deepseek-v3-671b"],
              n_experts=8, top_k=2, capacity_factor=4.0,   # high cap: no drops
              n_shared_experts=0)  # routed part only; shared tested below
key = jax.random.PRNGKey(0)
p = M.init_moe(key, cfg, jnp.float32)
T_tokens, d = 64, cfg.d_model
T = T_tokens
x = jax.random.normal(jax.random.PRNGKey(1), (T, d), jnp.float32)

y_ref, aux_ref = M.moe_apply_local(p, x, cfg, cdt=jnp.float32)

mesh = jax.make_mesh((2, 4), ("data", "model"))
pspec = M.spec_moe(cfg, make_rules(cfg, mesh), layer_stacked=False)
def body(p_loc, x_loc):
    return M.moe_apply(p_loc, x_loc, cfg, axis_name="model", cdt=jnp.float32)
y, aux = jax.jit(compat.shard_map(body, mesh=mesh,
                in_specs=(pspec, P("model", None)),
                out_specs=(P("model", None), P())))(p, x)
err = float(jnp.max(jnp.abs(y - y_ref))) / float(jnp.max(jnp.abs(y_ref)))

def body2(p_loc, x_loc):
    return M.moe_apply_replicated(p_loc, x_loc, cfg, axis_name="model", cdt=jnp.float32)
y2, _ = jax.jit(compat.shard_map(body2, mesh=mesh,
                in_specs=(pspec, P(None, None)),
                out_specs=(P(None, None), P())))(p, x)
err2 = float(jnp.max(jnp.abs(y2 - y_ref))) / float(jnp.max(jnp.abs(y_ref)))
# full-block equivalence incl. shared expert, through _moe_block
import dataclasses
from repro.models import transformer as T
cfg_s = dataclasses.replace(cfg, n_shared_experts=1)
p_s = M.init_moe(jax.random.PRNGKey(4), cfg_s, jnp.float32)
xb = x.reshape(2, T_tokens // 2, d)
y_ref_s, _ = M.moe_apply_local(p_s, x, cfg_s, cdt=jnp.float32)
rt = T.Runtime(cfg=cfg_s, mesh=mesh, rules=make_rules(cfg_s, mesh))
yb, _ = jax.jit(lambda p_, x_: T._moe_block(p_, x_, rt))(p_s, xb)
err3 = float(jnp.max(jnp.abs(yb.reshape(-1, d) - y_ref_s))) / float(jnp.max(jnp.abs(y_ref_s)))
print(f"a2a_err={err:.2e} replicated_err={err2:.2e} block_err={err3:.2e}")
sys.exit(0 if (err < 1e-5 and err2 < 1e-5 and err3 < 1e-5) else 1)
