"""Subprocess helper: Ulysses seq<->head attention == plain mea."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
import numpy as np, jax, jax.numpy as jnp
import repro  # noqa
from repro.models.attention import mea, ulysses_attention

key = jax.random.PRNGKey(0)
B, S, H, D = 2, 64, 8, 16
q = jax.random.normal(key, (B, S, H, D), jnp.float32)
k = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D), jnp.float32)
v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D), jnp.float32)
pos = jnp.arange(S, dtype=jnp.int32)
ref = mea(q, k, v, pos, pos)
mesh = jax.make_mesh((4,), ("model",))
out = jax.jit(lambda q, k, v: ulysses_attention(
    q, k, v, pos, pos, mesh, axis="model"))(q, k, v)
err = float(jnp.max(jnp.abs(out - ref)))
print(f"ulysses_err={err:.2e}")
sys.exit(0 if err < 1e-5 else 1)
