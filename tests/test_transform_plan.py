"""The unified Plan API: dispatch, precompute caching, describe()."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core import cache as plancache
from repro.core import grids, sht, spectra, transform

LMAX, K = 24, 2
KEY = jax.random.PRNGKey(7)


@pytest.fixture(autouse=True)
def _fresh_caches():
    """Each test sees empty plan/precompute caches and zeroed counters."""
    transform.clear_plan_cache()
    plancache.reset_stats()
    yield
    transform.clear_plan_cache()
    plancache.reset_stats()


def _oracle_pair():
    alm = sht.random_alm(KEY, LMAX, LMAX, K=K)
    oracle = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float64",
                             mode="jnp")
    maps = np.asarray(oracle.alm2map(alm))
    return alm, maps, np.asarray(oracle.map2alm(jnp.asarray(maps)))


# -- plan-signature cache ----------------------------------------------------


def test_make_plan_is_memoised():
    p1 = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float64", mode="model")
    builds = plancache.stats().builds
    p2 = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float64", mode="model")
    assert p2 is p1
    assert plancache.stats().builds == builds       # no recompute at all


def test_signature_distinguishes_problems():
    p1 = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float64", mode="model")
    p2 = repro.make_plan("gl", l_max=LMAX, K=K + 1, dtype="float64",
                         mode="model")
    p3 = repro.make_plan("gl", l_max=LMAX + 8, K=K, dtype="float64",
                         mode="model")
    assert p1 is not p2 and p1 is not p3 and p2 is not p3


def test_disk_cache_skips_recompute(tmp_path):
    d = str(tmp_path)
    p1 = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float32", mode="auto",
                         cache="disk", cache_dir=d)
    builds = plancache.stats().builds
    assert builds > 0
    # simulate a fresh process: drop every in-memory tier
    transform.clear_plan_cache()
    p2 = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float32", mode="auto",
                         cache="disk", cache_dir=d)
    assert p2 is not p1                              # new object...
    assert plancache.stats().builds == builds        # ...zero rebuilt payloads
    assert plancache.stats().disk_hits > 0
    assert p2.backends == p1.backends                # autotune decision reused
    assert p2.cache_events.get("decision") == "hit"


def test_clear_plan_cache_disk_tier(tmp_path):
    """clear_plan_cache(disk=True) removes the persistent entries too.

    Regression: a bare clear_plan_cache() left stale .npz/.json entries
    under the cache dir, so a later cache="disk" plan silently resurrected
    payloads the caller believed cleared.
    """
    import os
    d = str(tmp_path)
    repro.make_plan("gl", l_max=LMAX, K=1, dtype="float32", mode="auto",
                    cache="disk", cache_dir=d)
    entries = [f for f in os.listdir(d) if f.endswith((".npz", ".json"))]
    assert entries, "disk tier should have been populated"
    # default clear keeps the disk tier (documented behaviour) ...
    transform.clear_plan_cache()
    assert [f for f in os.listdir(d) if f.endswith((".npz", ".json"))]
    # ... disk=True wipes it: a rebuild must not see a single disk hit
    transform.clear_plan_cache(disk=True, directory=d)
    assert not [f for f in os.listdir(d) if f.endswith((".npz", ".json"))]
    plancache.reset_stats()
    repro.make_plan("gl", l_max=LMAX, K=1, dtype="float32", mode="auto",
                    cache="disk", cache_dir=d)
    assert plancache.stats().disk_hits == 0
    assert plancache.stats().builds > 0
    # foreign files are never touched
    alien = os.path.join(d, "keep.me")
    with open(alien, "w") as f:
        f.write("not a cache entry")
    transform.clear_plan_cache(disk=True, directory=d)
    assert os.path.exists(alien)


def test_disk_cache_keys_distinguish_layout_and_spin(tmp_path):
    """Signature keys must not collide across spin / layout variants.

    A spin-2 plan's seed tables have different shapes than the scalar
    ones; a key collision would resurrect the wrong payload from disk and
    crash (or worse, silently corrupt) the kernel stage.
    """
    d = str(tmp_path)
    p0 = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float32",
                         mode="pallas_vpu", cache="disk", cache_dir=d)
    p2 = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float32",
                         mode="pallas_vpu", spin=2, cache="disk", cache_dir=d)
    s0 = p0._seeds()
    s2 = p2._seeds_spin()
    assert p0.cache_events["seeds"] != p2.cache_events["seeds_spin"]
    # fold changes the seed table layout -> its own key
    pf = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float32",
                         mode="pallas_vpu", fold=True, cache="disk",
                         cache_dir=d)
    sf = pf._seeds()
    assert pf.cache_events["seeds"] != p0.cache_events["seeds"]
    assert sf[0].shape != s0[0].shape
    # cold reload from disk returns the right payload for each signature
    transform.clear_plan_cache()
    q0 = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float32",
                         mode="pallas_vpu", cache="disk", cache_dir=d)
    q2 = repro.make_plan("gl", l_max=LMAX, K=1, dtype="float32",
                         mode="pallas_vpu", spin=2, cache="disk", cache_dir=d)
    np.testing.assert_array_equal(np.asarray(q0._seeds()[0]),
                                  np.asarray(s0[0]))
    np.testing.assert_array_equal(np.asarray(q2._seeds_spin()[0]),
                                  np.asarray(s2[0]))


def test_geometry_payload_roundtrip(tmp_path):
    """A disk-cached GL grid is bit-identical to a fresh one."""
    d = str(tmp_path)
    p1 = repro.make_plan("gl", l_max=33, dtype="float64", mode="jnp",
                         cache="disk", cache_dir=d)
    transform.clear_plan_cache()
    p2 = repro.make_plan("gl", l_max=33, dtype="float64", mode="jnp",
                         cache="disk", cache_dir=d)
    g_ref = grids.make_grid("gl", l_max=33)
    for g in (p1.grid, p2.grid):
        np.testing.assert_array_equal(g.cos_theta, g_ref.cos_theta)
        np.testing.assert_array_equal(g.weights, g_ref.weights)


# -- backend agreement -------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas_vpu", "pallas_mxu"])
@pytest.mark.parametrize("fold", [False, True])
def test_backends_agree_with_f64_oracle(backend, fold):
    alm, maps_ref, alm_ref = _oracle_pair()
    dtype = "float64" if backend == "jnp" else "float32"
    p = repro.make_plan("gl", l_max=LMAX, K=K, dtype=dtype, mode=backend,
                        fold=fold)
    tol = 1e-12 if dtype == "float64" else 1e-4
    m = np.asarray(p.alm2map(alm.astype(jnp.complex64)
                             if dtype == "float32" else alm))
    assert np.max(np.abs(m - maps_ref)) / np.max(np.abs(maps_ref)) < tol
    a = np.asarray(p.map2alm(jnp.asarray(maps_ref, p.dtype)))
    assert np.max(np.abs(a - alm_ref)) / np.max(np.abs(alm_ref)) < tol


def test_auto_and_model_modes_roundtrip():
    for mode in ("auto", "model"):
        p = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float32", mode=mode)
        assert p.backends["synth"] in p.candidates
        assert p.backends["anal"] in p.candidates
        alm = sht.random_alm(KEY, LMAX, LMAX, K=K).astype(jnp.complex64)
        err = spectra.d_err(alm, p.map2alm(p.alm2map(alm)))
        assert err < 1e-4, (mode, err)


def test_float64_restricted_to_oracle():
    p = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float64", mode="auto")
    assert p.candidates == ["jnp"] or "pallas_vpu" not in p.candidates
    assert p.backends == {"synth": "jnp", "anal": "jnp"}


def test_dist_backend_requires_devices():
    if jax.device_count() >= 2:
        pytest.skip("multi-device host: dist is legitimately available")
    with pytest.raises(ValueError):
        repro.make_plan("gl", l_max=LMAX, K=K, dtype="float64", mode="dist")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="dist backend needs >= 2 devices (covered by "
                           "tests/helpers/dist_sht_check.py in a subprocess)")
def test_dist_backend_agrees():  # pragma: no cover - TPU/multi-device hosts
    alm, maps_ref, _ = _oracle_pair()
    p = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float64", mode="dist")
    m = np.asarray(p.alm2map(alm))
    assert np.max(np.abs(m - maps_ref)) / np.max(np.abs(maps_ref)) < 1e-10


def test_map2alm_iters_refines_on_healpix():
    p = repro.make_plan("healpix_ring", nside=8, dtype="float64", mode="jnp")
    alm = sht.random_alm(KEY, p.l_max, p.m_max, K=1)
    maps = p.alm2map(alm)
    e0 = spectra.d_err(alm, p.map2alm(maps))
    e1 = spectra.d_err(alm, p.map2alm(maps, iters=1))
    assert e1 < e0 / 3                               # Jacobi refinement bites


# -- describe() --------------------------------------------------------------


def test_describe_well_formed():
    p = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float32", mode="auto")
    d = p.describe()
    for key in ("signature", "mode", "backends", "candidates", "predicted_s",
                "measured_s", "work", "memory", "cache"):
        assert key in d, key
    assert d["signature"]["l_max"] == LMAX
    assert set(d["backends"]) == {"synth", "anal"}
    for b in d["candidates"]:
        assert {"synth", "anal"} <= set(d["predicted_s"][b])
        assert all(d["predicted_s"][b][direction] > 0
                   for direction in ("synth", "anal"))
        if b.startswith("pallas"):
            # pallas candidates carry the packed/plain/fused layout decision
            assert d["predicted_s"][b]["synth_layout"] in (
                "packed", "plain", "fused")
        for direction in ("synth", "anal"):
            assert direction in d["measured_s"][b]
    assert d["memory"]["total_bytes"] > 0
    assert d["work"]["n_lm"] == (LMAX + 1) * (LMAX + 2) // 2
    # report() renders every section without blowing up
    r = p.report()
    assert "synth ->" in r and "anal" in r and "cache" in r


def test_describe_predicted_vs_measured_present_in_auto():
    p = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float32", mode="auto")
    d = p.describe()
    chosen = d["backends"]["synth"]
    assert np.isfinite(d["measured_s"][chosen]["synth"])
    assert d["measured_s"][chosen]["synth"] > 0


def test_plan_shape_validation():
    p = repro.make_plan("gl", l_max=LMAX, K=K, dtype="float64", mode="jnp")
    with pytest.raises(AssertionError):
        p.alm2map(jnp.zeros((LMAX + 1, LMAX + 1, K + 1), jnp.complex128))
    with pytest.raises(AssertionError):
        p.map2alm(jnp.zeros((3, 4, K)))


def test_available_backends_policy():
    g = grids.make_grid("gl", l_max=16)
    assert repro.available_backends(g, "float64", 1) == ["jnp"]
    f32 = repro.available_backends(g, "float32", 1)
    assert "pallas_vpu" in f32 and "pallas_mxu" in f32
    # raggedness is no longer a restriction: the bucket phase stage serves
    # every backend
    ragged = grids.make_grid("healpix", nside=4)
    assert repro.available_backends(ragged, "float32", 1) == f32
    assert repro.available_backends(ragged, "float32", 4) == f32 + ["dist"]


def test_backend_eligibility_reasons():
    g = grids.make_grid("gl", l_max=16)
    elig = transform.backend_eligibility(g, "float64", 1)
    assert elig["jnp"] is None
    assert "float32" in elig["pallas_vpu"]
    assert "devices" in elig["dist"]
    assert transform.backend_eligibility(g, "float32", 2)["dist"] is None


def test_describe_reports_skip_reasons():
    p = repro.make_plan("healpix", nside=4, K=1, dtype="float64", mode="jnp")
    d = p.describe()
    assert "float32" in d["skipped"]["pallas_vpu"]
    assert all(b not in d["candidates"] for b in d["skipped"])
    assert d["phase"]["kind"] == "bucket"
    assert d["phase"]["n_buckets"] >= 2
    r = p.report()
    assert "skipped pallas_vpu" in r and "phase: bucket" in r


# -- ragged (true HEALPix) grids through the full dispatch stack -------------


def _healpix_oracle_pair(nside=4):
    p = repro.make_plan("healpix", nside=nside, K=K, dtype="float64",
                        mode="jnp")
    alm = sht.random_alm(KEY, p.l_max, p.m_max, K=K)
    maps = np.asarray(p.alm2map(alm))
    return p, alm, maps, np.asarray(p.map2alm(jnp.asarray(maps)))


@pytest.mark.parametrize("backend", ["jnp", "pallas_vpu", "pallas_mxu"])
def test_healpix_backends_agree_with_f64_oracle(backend):
    _, alm, maps_ref, alm_ref = _healpix_oracle_pair()
    dtype = "float64" if backend == "jnp" else "float32"
    p = repro.make_plan("healpix", nside=4, K=K, dtype=dtype, mode=backend)
    tol = 1e-12 if dtype == "float64" else 1e-4
    m = np.asarray(p.alm2map(alm.astype(jnp.complex64)
                             if dtype == "float32" else alm))
    assert np.max(np.abs(m - maps_ref)) / np.max(np.abs(maps_ref)) < tol
    a = np.asarray(p.map2alm(jnp.asarray(maps_ref, p.dtype)))
    assert np.max(np.abs(a - alm_ref)) / np.max(np.abs(alm_ref)) < tol


def test_healpix_auto_mode_roundtrips():
    p = repro.make_plan("healpix", nside=4, K=K, dtype="float32",
                        mode="auto")
    assert p.backends["synth"] in p.candidates
    alm = sht.random_alm(KEY, p.l_max, p.m_max, K=K).astype(jnp.complex64)
    err = spectra.d_err(alm, p.map2alm(p.alm2map(alm)))
    assert err < 0.1                     # quadrature-level, not precision


@pytest.mark.parametrize("kind", ["healpix", "healpix_ring"])
def test_map2alm_iters_monotone_on_approximate_grids(kind):
    """Jacobi refinement must reduce the quadrature error monotonically on
    both HEALPix variants (paper §5 accuracy discussion)."""
    p = repro.make_plan(kind, nside=8, dtype="float64", mode="jnp")
    alm = sht.random_alm(KEY, p.l_max, p.m_max, K=1)
    maps = p.alm2map(alm)
    errs = [spectra.d_err(alm, p.map2alm(maps, iters=i)) for i in range(3)]
    assert errs[1] < errs[0] / 3         # first pass bites hard
    assert errs[2] < errs[1]             # and keeps shrinking
