"""Per-architecture smoke tests (reduced configs, CPU, 1 device):
one forward/train step + prefill/decode, asserting shapes and finiteness;
plus spec-tree/param-tree structural agreement (the sharding contract)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro  # noqa: F401
from repro.configs import registry
from repro.configs.base import SHAPES, reduced
from repro.models.model import input_specs, make_bundle

KEY = jax.random.PRNGKey(0)
ALL_ARCHS = sorted(registry.ARCHS)


def _batch(cfg, B=2, S=32):
    if cfg.is_encoder_decoder:
        return {"frames": jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.float32),
                "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision_stub":
        return {"tokens": jax.random.randint(KEY, (B, S - 8), 0, cfg.vocab),
                "patch_embeds": jax.random.normal(KEY, (B, 8, cfg.d_model),
                                                  jnp.float32)}
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_smoke(name):
    cfg = reduced(registry.ARCHS[name])
    b = make_bundle(cfg, mesh=None)
    params = b.init(KEY)
    loss = jax.jit(b.loss_fn)(params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert loss.shape == ()


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_smoke(name):
    cfg = reduced(registry.ARCHS[name])
    b = make_bundle(cfg, mesh=None)
    params = b.init(KEY)
    B = 2
    caches = b.init_caches(B, 64, enc_len=16) if cfg.is_encoder_decoder \
        else b.init_caches(B, 64)
    if cfg.is_encoder_decoder:
        batch = {"frames": jax.random.normal(KEY, (B, 16, cfg.d_model),
                                             jnp.float32),
                 "tokens": jax.random.randint(KEY, (B, 8), 0, cfg.vocab)}
        plen = 8
    else:
        batch = {"tokens": jax.random.randint(KEY, (B, 16), 0, cfg.vocab)}
        plen = 16
    logits, caches = jax.jit(b.prefill_fn)(params, batch, caches)
    assert logits.shape == (B, cfg.vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, _ = jax.jit(b.decode_fn)(params, tok, jnp.int32(plen), caches)
    assert np.all(np.isfinite(np.asarray(logits2)))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_param_specs_match_param_tree(name):
    """The sharding contract: specs tree must mirror the params tree."""
    cfg = reduced(registry.ARCHS[name])
    b = make_bundle(cfg, mesh=None)
    shapes = jax.eval_shape(b.init, KEY)
    specs = b.param_specs()
    # identical treedefs (specs leaves are PartitionSpec)
    from jax.sharding import PartitionSpec as P
    s1 = jax.tree.structure(shapes)
    s2 = jax.tree.structure(specs, is_leaf=lambda v: isinstance(v, P))
    assert s1 == s2, f"{name}: spec tree != param tree"
    # every spec fits its array rank
    def ok(a, s):
        assert len(s) <= len(a.shape), (a.shape, s)
        return None
    jax.tree.map(ok, shapes, specs, is_leaf=lambda v: isinstance(v, P))


@pytest.mark.parametrize("name", ["qwen3-8b", "deepseek-v3-671b",
                                  "xlstm-125m", "recurrentgemma-9b"])
def test_cache_specs_match_cache_tree(name):
    from jax.sharding import PartitionSpec as P
    cfg = reduced(registry.ARCHS[name])
    b = make_bundle(cfg, mesh=None)
    caches = jax.eval_shape(lambda: b.init_caches(2, 32))
    specs = b.cache_specs()
    s1 = jax.tree.structure(caches)
    s2 = jax.tree.structure(specs, is_leaf=lambda v: isinstance(v, P))
    assert s1 == s2


def test_prefill_matches_stepwise_decode():
    """Prefill-then-decode == token-by-token decode (cache correctness)."""
    cfg = reduced(registry.ARCHS["qwen3-8b"])
    b = make_bundle(cfg, mesh=None)
    params = b.init(KEY)
    B, S = 1, 8
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    c1 = b.init_caches(B, 32)
    logits_p, c1 = jax.jit(b.prefill_fn)(params, {"tokens": toks}, c1)
    c2 = b.init_caches(B, 32)
    dec = jax.jit(b.decode_fn)
    for t in range(S):
        logits_d, c2 = dec(params, toks[:, t:t + 1], jnp.int32(t), c2)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(logits_d),
                               rtol=0, atol=2e-4)


def test_sliding_window_cache_bounded():
    cfg = reduced(registry.ARCHS["h2o-danube-3-4b"])
    assert cfg.sliding_window == 64
    b = make_bundle(cfg, mesh=None)
    caches = jax.eval_shape(lambda: b.init_caches(2, 4096))
    k = caches[0][0]["k"]
    assert k.shape[2] == 64                 # ring buffer, not 4096


def test_mla_cache_is_compressed():
    cfg = reduced(registry.ARCHS["deepseek-v3-671b"])
    b = make_bundle(cfg, mesh=None)
    caches = jax.eval_shape(lambda: b.init_caches(2, 128))
    leaf = caches[-1][0]
    assert "ckv" in leaf and leaf["ckv"].shape[-1] == cfg.kv_lora_rank
    dense_bytes = 2 * cfg.n_heads * cfg.hd
    mla_bytes = cfg.kv_lora_rank + cfg.qk_rope_dim
    assert mla_bytes < dense_bytes           # the MLA serving win


def test_ssm_state_constant_in_seq_len():
    cfg = reduced(registry.ARCHS["xlstm-125m"])
    b = make_bundle(cfg, mesh=None)
    c1 = jax.eval_shape(lambda: b.init_caches(2, 128))
    c2 = jax.eval_shape(lambda: b.init_caches(2, 1 << 19))
    n1 = sum(np.prod(l.shape) for l in jax.tree.leaves(c1))
    n2 = sum(np.prod(l.shape) for l in jax.tree.leaves(c2))
    assert n1 == n2                          # O(1) state => long_500k works
